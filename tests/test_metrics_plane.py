"""Live metrics plane + SLO subsystem: log-bucket histograms, windowed
rotation, virtual-clock gauges, burn-rate alerts, fault injection, per-tier
queue depths, closed-loop arrivals, and the exporters/tools on top.

The tentpole contracts under test:

* log-bucket quantiles agree with exact nearest-rank within the configured
  relative error; merge is exact (bucket-wise addition);
* window rotation never loses counts (``total.count == dropped + live``);
* the disabled plane allocates nothing, and an enabled plane attached to
  the event loop leaves completions bit-identical (sampling is read-only);
* a Degradation on a device stretches only the interleaved timing after
  its start — serial pricing and all priced accounting stay fault-blind;
* the SLO monitor fires on the rising edge of a multi-window burn and the
  serve-style degradation is detected within a bounded virtual delay.
"""

import importlib.util
import json
import random
from pathlib import Path

import pytest

from repro.core.io_sim import DRAM, NVME, S3, Degradation
from repro.obs import (
    NULL_PLANE,
    NULL_TRACER,
    BurnWindow,
    GaugeSeries,
    LogBucketHistogram,
    MetricsPlane,
    MetricsRegistry,
    SLObjective,
    SLOMonitor,
    Tracer,
    WindowedHistogram,
    percentile,
    prometheus_text,
)
from repro.store import EventLoop, Job, QoS, build_job
from repro.store.stats import DrainRecord

ROOT = Path(__file__).resolve().parent.parent


def _load_module(path, name):
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _rec(label, tiers, n_requests=1):
    """Shorthand synthetic drain: tiers = {tier: (ops, nbytes, phase)}."""
    return DrainRecord(label, n_requests,
                       {t: ({p: ops}, {p: nb})
                        for t, (ops, nb, p) in tiers.items()})


# ---------------------------------------------------------------------------
# log-bucket histogram
# ---------------------------------------------------------------------------


def test_log_bucket_quantiles_within_relative_error():
    rng = random.Random(42)
    for rel_err in (0.05, 0.01):
        h = LogBucketHistogram(rel_err)
        xs = [rng.lognormvariate(0.0, 2.0) for _ in range(4000)]
        for x in xs:
            h.observe(x)
        for q in (1, 10, 25, 50, 75, 90, 99, 99.9):
            exact = percentile(xs, q)
            approx = h.quantile(q)
            assert abs(approx - exact) <= rel_err * exact * 1.0001, \
                (rel_err, q, exact, approx)


def test_log_bucket_extremes_and_zeros_exact():
    h = LogBucketHistogram(0.01)
    for v in (0.0, 0.0, 3.5, 700.25):
        h.observe(v)
    assert h.min == 0.0 and h.max == 700.25
    assert h.quantile(0) == 0.0 and h.quantile(100) == 700.25
    assert h.quantile(50) == 0.0                 # 2 of 4 samples are zero
    assert h.count == 4 and h.sum == pytest.approx(703.75)
    with pytest.raises(ValueError):
        h.observe(-1.0)


def test_log_bucket_merge_is_exact():
    rng = random.Random(7)
    xs = [rng.expovariate(1.0) for _ in range(500)]
    ys = [rng.expovariate(0.1) for _ in range(300)]
    both = LogBucketHistogram(0.02)
    for v in xs + ys:
        both.observe(v)
    a = LogBucketHistogram(0.02)
    b = LogBucketHistogram(0.02)
    for v in xs:
        a.observe(v)
    for v in ys:
        b.observe(v)
    a.merge(b)
    assert a.buckets == both.buckets
    assert a.count == both.count and a.sum == pytest.approx(both.sum)
    assert a.min == both.min and a.max == both.max
    with pytest.raises(ValueError):
        a.merge(LogBucketHistogram(0.01))   # mismatched rel_err


def test_log_bucket_empty_summary_and_quantile():
    h = LogBucketHistogram()
    s = h.summary()
    assert s == {"count": 0, "mean": None, "p50": None, "p99": None,
                 "p999": None, "max": None}
    with pytest.raises(ValueError):
        h.quantile(50)


# ---------------------------------------------------------------------------
# windowed histogram
# ---------------------------------------------------------------------------


def test_window_rotation_never_loses_counts():
    w = WindowedHistogram(window=1.0, n_windows=4, rel_err=0.01)
    rng = random.Random(0)
    n = 0
    for _ in range(500):
        t = rng.uniform(0, 40)
        w.observe(t, rng.uniform(0.1, 10))
        n += 1
        live = w.live_count   # lazy expiry may move counts into dropped
        assert w.total.count == w.dropped + live
    assert w.total.count == n


def test_window_live_horizon_and_straggler():
    w = WindowedHistogram(window=1.0, n_windows=2, rel_err=0.01)
    w.observe(0.5, 1.0)
    w.observe(1.5, 2.0)
    assert w.live_count == 2
    w.observe(2.5, 3.0)       # rotates window 0 out (slot reuse)
    assert w.live_count == 2 and w.dropped == 1
    w.observe(0.1, 9.0)       # straggler older than the whole horizon
    assert w.live_count == 2 and w.dropped == 2
    assert w.total.count == 4
    merged = w.merged()
    assert merged.count == 2
    assert w.quantile(100) == pytest.approx(3.0, rel=0.01)


def test_window_summary_shape():
    w = WindowedHistogram(window=0.5, n_windows=4)
    w.observe(0.1, 0.25)
    s = w.summary()
    assert s["count"] == 1 and s["lifetime_count"] == 1
    assert s["window_s"] == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# gauges + plane
# ---------------------------------------------------------------------------


def test_gauge_series_export_downsamples_deterministically():
    g = GaugeSeries("x")
    for i in range(100):
        g.sample(i * 0.1, float(i))
    full = g.export()
    assert full["n_samples"] == 100 and len(full["t"]) == 100
    small = g.export(max_points=10)
    assert len(small["t"]) <= 11 and small["v"][-1] == 99.0
    assert small == g.export(max_points=10)   # deterministic
    assert g.between(1.0, 2.0) == [10.0 + k for k in range(10)]


def test_disabled_plane_allocates_nothing():
    assert not NULL_PLANE.enabled
    NULL_PLANE.sample("tier.x.utilization", 1.0, 0.5)
    NULL_PLANE.observe_latency("lat.t", 1.0, 0.1)
    assert NULL_PLANE.series == {} and NULL_PLANE.latency == {}


def test_plane_prometheus_and_export_are_json_safe():
    p = MetricsPlane(window=0.5, n_windows=4)
    p.counter("slo.breach.premium").inc(2)
    p.sample("tier.nvme.utilization", 0.5, 0.75)
    p.observe_latency("latency.premium", 0.5, 0.004)
    text = p.prometheus_text()
    assert "# TYPE slo_breach_premium counter" in text
    assert "slo_breach_premium 2" in text
    assert "# TYPE tier_nvme_utilization gauge" in text
    assert "latency_premium_bucket" in text and 'le="+Inf"' in text
    assert "latency_premium_count 1" in text
    # export is embeddable in the NaN-refusing bench artifact writer
    blob = json.dumps(p.export(), allow_nan=False)
    back = json.loads(blob)
    assert back["counters"] == {"slo.breach.premium": 2}
    assert back["series"]["tier.nvme.utilization"]["v"] == [0.75]


def test_plane_to_trace_emits_virtual_clock_counters():
    p = MetricsPlane()
    p.sample("tier.nvme.utilization", 0.25, 0.5)
    p.sample("tier.nvme.utilization", 0.75, 1.0)
    tr = Tracer()
    n = p.to_trace(tr)
    assert n == 2
    evs = [e for e in tr.events if e["ph"] == "C"]
    assert [e["ts"] for e in evs] == [0.25e6, 0.75e6]
    assert evs[0]["args"] == {"value": 0.5}


def test_tracer_counter_ts_override():
    tr = Tracer()
    tr.counter("c", {"v": 1.0}, ts=123.0)
    tr.counter("c", {"v": 2.0})
    assert tr.events[0]["ts"] == 123.0
    assert tr.events[1]["ts"] != 123.0


# ---------------------------------------------------------------------------
# registry satellites: empty-histogram summary, summaries(), prometheus_text
# ---------------------------------------------------------------------------


def test_empty_histogram_summary_is_none_valued():
    reg = MetricsRegistry()
    h = reg.histogram("lat")
    assert h.summary() == {"count": 0, "mean": None, "p50": None,
                           "p99": None, "p999": None, "max": None}
    json.dumps(h.summary(), allow_nan=False)   # NaN-free policy


def test_registry_summaries_prefix_snapshot():
    reg = MetricsRegistry()
    reg.histogram("a.x").observe(1.0)
    reg.histogram("a.y")          # empty: must not raise
    reg.histogram("b.z").observe(2.0)
    snap = reg.summaries("a.")
    assert sorted(snap) == ["a.x", "a.y"]
    assert snap["a.x"]["count"] == 1 and snap["a.y"]["count"] == 0


def test_prometheus_text_from_registry():
    reg = MetricsRegistry()
    reg.counter("decode.fallback.fullzip.float-values").inc(3)
    reg.histogram("take.lat").observe_many([1.0, 2.0, 3.0])
    text = prometheus_text(reg)
    assert "# TYPE decode_fallback_fullzip_float_values counter" in text
    assert "decode_fallback_fullzip_float_values 3" in text
    assert 'take_lat{quantile="0.5"} 2.0' in text
    assert "take_lat_count 3" in text


# ---------------------------------------------------------------------------
# Degradation model
# ---------------------------------------------------------------------------


def test_degradation_schedule_and_compounding():
    d1 = Degradation(start=1.0, end=2.0, latency_factor=4.0)
    d2 = Degradation(start=1.5, latency_factor=2.0, throughput_factor=0.5)
    dev = NVME.with_fault(d1).with_fault(d2)
    assert NVME.faults == ()              # frozen base is untouched
    assert dev.latency_factor_at(0.5) == 1.0
    assert dev.latency_factor_at(1.2) == 4.0
    assert dev.latency_factor_at(1.7) == 8.0     # overlap compounds
    assert dev.latency_factor_at(2.5) == 2.0     # d1 expired, d2 open-ended
    assert dev.bandwidth_factor_at(1.7) == 0.5
    with pytest.raises(ValueError):
        Degradation(start=0.0, latency_factor=0.0)
    with pytest.raises(ValueError):
        Degradation(start=2.0, end=1.0)


def test_fault_stretches_interleaved_only_after_start():
    rec = _rec("take", {0: (64, 1 << 20, 0)})
    dev_ok = NVME
    dev_bad = NVME.with_fault(Degradation(start=100.0, latency_factor=50.0,
                                          throughput_factor=0.1))
    job_a = build_job(rec, [dev_ok])
    job_b = build_job(rec, [dev_ok])
    base = EventLoop([dev_ok], queue_depth=8).run([job_a]).makespan
    # fault starts far in the future: timing identical
    pre = EventLoop([dev_bad], queue_depth=8).run([job_b]).makespan
    assert pre == base
    # fault active from t=0: strictly slower
    dev_now = NVME.with_fault(Degradation(start=0.0, latency_factor=50.0,
                                          throughput_factor=0.1))
    job_c = build_job(rec, [dev_ok])
    hot = EventLoop([dev_now], queue_depth=8).run([job_c]).makespan
    assert hot > base
    # serial pricing is fault-blind: identical under both devices
    job_d = build_job(rec, [dev_ok])
    s_ok = EventLoop([dev_ok], queue_depth=8).run([job_d], mode="serial")
    s_bad = EventLoop([dev_now], queue_depth=8).run([job_d], mode="serial")
    assert s_ok.completions == s_bad.completions


# ---------------------------------------------------------------------------
# event-loop sampling: bit-identity + utilization saturation
# ---------------------------------------------------------------------------


def _jobs(n=20, submit_gap=0.001):
    jobs = []
    for i in range(n):
        rec = _rec(f"take#{i}", {0: (16, 256 << 10, 0), 1: (2, 64 << 10, 0)})
        jobs.append(build_job(rec, [NVME, S3], tenant="t",
                              submit=i * submit_gap, seq=i))
    return jobs


def test_plane_sampling_leaves_completions_bit_identical():
    plain = EventLoop([NVME, S3], queue_depth=8).run(_jobs())
    plane = MetricsPlane(window=0.01, n_windows=8)
    slo = SLOMonitor({"t": SLObjective(0.5)}, registry=plane.registry,
                     plane=plane)
    sampled = EventLoop([NVME, S3], queue_depth=8, plane=plane,
                        slo=slo).run(_jobs())
    assert sampled.completions == plain.completions
    assert sampled.tiers == plain.tiers
    # ... and the plane actually collected the documented gauges
    names = set(plane.series)
    assert f"tier.{NVME.name}.utilization" in names
    assert f"tier.{NVME.name}.outstanding" in names
    assert f"tier.{NVME.name}.pipe_backlog" in names
    assert "jobs.in_flight" in names
    assert plane.latency["latency.t"].total.count == len(plain.completions)


def test_degraded_utilization_saturates_and_slo_fires():
    # arrivals spread over ~0.6s so NVMe rounds are still being issued when
    # the fault starts mid-run
    jobs = _jobs(n=60, submit_gap=0.01)
    healthy = EventLoop([NVME, S3], queue_depth=8).run(_jobs(60, 0.01))
    t_deg = 0.2
    bad = NVME.with_fault(Degradation(start=t_deg, latency_factor=300.0,
                                      throughput_factor=0.01))
    plane = MetricsPlane(window=0.05, n_windows=8)
    lat = [c.latency for c in healthy.completions]
    obj = SLObjective(latency_s=max(lat) * 1.1, target=0.99)
    slo = SLOMonitor({"t": obj},
                     windows=(BurnWindow(0.2, 0.025, 2.0),),
                     registry=plane.registry, plane=plane)
    EventLoop([bad, S3], queue_depth=8, plane=plane, slo=slo).run(jobs)
    util = plane.series[f"tier.{NVME.name}.utilization"]
    post = util.between(t_deg, float("inf"))
    assert post and max(post) > 0.9
    alert = slo.first_alert("t")
    assert alert is not None and alert.at >= t_deg
    assert plane.registry.counter("slo.breach.t").value >= 1


# ---------------------------------------------------------------------------
# per-tier queue depths
# ---------------------------------------------------------------------------


def test_per_tier_queue_depth_lone_job_degeneration():
    rec = _rec("take", {0: (64, 1 << 20, 0), 1: (10, 2 << 20, 1)})
    depths = {NVME.name: 4, S3.name: 2}
    job = build_job(rec, [NVME, S3])
    serial = job.serial_time(256, depths)
    lone = EventLoop([NVME, S3], queue_depth=256,
                     queue_depths=depths).run([build_job(rec, [NVME, S3])])
    assert lone.completions[0].done == pytest.approx(serial, rel=1e-12)
    # the override really binds: shallower NVMe depth costs more rounds
    assert serial > job.serial_time(256)


def test_per_tier_depth_falls_back_to_shared():
    rec = _rec("take", {0: (64, 1 << 20, 0)})
    job = build_job(rec, [NVME])
    assert job.serial_time(8, {"some_other_dev": 2}) \
        == job.serial_time(8)
    loop = EventLoop([NVME, S3], queue_depth=8, queue_depths={S3.name: 2})
    assert loop.qd_for(NVME) == 8 and loop.qd_for(S3) == 2


# ---------------------------------------------------------------------------
# SLO monitor semantics
# ---------------------------------------------------------------------------


def test_burn_rate_math_and_rising_edge():
    reg = MetricsRegistry()
    tr = Tracer()
    mon = SLOMonitor({"t": SLObjective(latency_s=0.01, target=0.9)},
                     windows=(BurnWindow(1.0, 0.25, 2.0),),
                     tracer=tr, registry=reg)
    # 10% budget; burn >= 2 needs bad fraction >= 0.2 in both windows
    t = 0.0
    for _ in range(20):
        t += 0.01
        mon.observe("t", t, 0.001)       # all good: no alert
    assert mon.alerts == []
    for _ in range(20):
        t += 0.01
        mon.observe("t", t, 0.05)        # all bad: fires once
    assert len(mon.alerts) == 1
    a = mon.alerts[0]
    assert a.burn_long >= 2.0 and a.burn_short >= 2.0
    assert reg.counter("slo.breach.t").value == 1
    assert any(e["name"] == "slo_breach:t" for e in tr.events)
    # recovery resets the latch; a second incident fires a second alert
    for _ in range(200):
        t += 0.01
        mon.observe("t", t, 0.001)
    for _ in range(40):
        t += 0.01
        mon.observe("t", t, 0.05)
    assert len(mon.alerts) == 2
    assert reg.counter("slo.requests.t").value == 280
    assert reg.counter("slo.bad.t").value == 60


def test_slo_monitor_ignores_tenants_without_objective():
    mon = SLOMonitor({"premium": SLObjective(0.01)})
    mon.observe("standard", 1.0, 99.0)
    assert mon.alerts == [] and mon.table()[0]["requests"] == 0


def test_slo_table_shape():
    mon = SLOMonitor({"p": SLObjective(0.02, 0.95)})
    mon.observe("p", 0.1, 0.001)
    mon.observe("p", 0.2, 0.5)
    (row,) = mon.table()
    assert row["tenant"] == "p" and row["requests"] == 2 and row["bad"] == 1
    assert row["bad_fraction"] == pytest.approx(0.5)
    assert row["objective_ms"] == pytest.approx(20.0)
    json.dumps(mon.table(), allow_nan=False)


# ---------------------------------------------------------------------------
# closed-loop arrivals
# ---------------------------------------------------------------------------


def test_closed_loop_chain_orders_requests_per_client():
    # two chained jobs for one client: the second arrives think after the
    # first completes, in both interleaved and serial pricing
    rec = _rec("take", {0: (16, 256 << 10, 0)})
    a = build_job(rec, [NVME], seq=1)
    b = build_job(rec, [NVME], seq=2)
    b.after, b.think = a, 0.5
    for mode in ("interleaved", "serial"):
        res = EventLoop([NVME], queue_depth=8).run([a, b], mode=mode)
        ca = next(c for c in res.completions if c.submit < 0.5)
        cb = next(c for c in res.completions if c.submit >= 0.5)
        assert cb.submit == pytest.approx(ca.done + 0.5)
        assert cb.latency == pytest.approx(ca.latency)  # no queueing either


def test_closed_loop_dependency_outside_run_is_ignored():
    rec = _rec("take", {0: (4, 4096, 0)})
    ghost = build_job(rec, [NVME], seq=1)
    dep = build_job(rec, [NVME], seq=2)
    dep.after, dep.think = ghost, 99.0
    res = EventLoop([NVME], queue_depth=8).run([dep])
    assert len(res.completions) == 1 and res.completions[0].submit == 0.0


def test_zipf_closed_generation_and_open_bit_identity():
    from repro.serve.workload import TenantSpec, ZipfWorkload
    tenants = [TenantSpec("a", share=1.0), TenantSpec("b", share=1.0)]
    base = ZipfWorkload(1000, tenants, 50, seed=5).generate()
    # new knobs must not perturb the open-loop stream (seed behaviour)
    same = ZipfWorkload(1000, tenants, 50, seed=5, arrival="open",
                        think_time=9.0, clients_per_tenant=7).generate()
    assert [(r.tenant, r.at, r.rows.tolist()) for r in base] \
        == [(r.tenant, r.at, r.rows.tolist()) for r in same]
    assert all(r.client is None for r in base)
    closed = ZipfWorkload(1000, tenants, 50, seed=5, arrival="closed",
                          clients_per_tenant=3).generate()
    assert all(r.at == 0.0 and r.client for r in closed)
    # round-robin client assignment within each tenant
    a_clients = [r.client for r in closed if r.tenant == "a"]
    assert a_clients[:4] == ["a/c0", "a/c1", "a/c2", "a/c0"][:len(a_clients)]
    with pytest.raises(ValueError):
        ZipfWorkload(1000, tenants, 5, arrival="drip")


def test_zipf_slo_objectives_from_tenant_spec():
    from repro.serve.workload import TenantSpec, ZipfWorkload
    tenants = [TenantSpec("p", slo_ms=5.0, slo_target=0.999),
               TenantSpec("s")]
    wl = ZipfWorkload(100, tenants, 5)
    objs = wl.slo_objectives()
    assert set(objs) == {"p"}
    assert objs["p"].latency_s == pytest.approx(0.005)
    assert objs["p"].target == 0.999


# ---------------------------------------------------------------------------
# tools: bench_gate slo strictness, bench_history, obs_report
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def bench_gate():
    return _load_module(ROOT / "tools" / "bench_gate.py", "bench_gate_mp")


def test_bench_gate_slo_keys_always_strict(bench_gate):
    base = {"slo": {"degraded": {"requests_per_s": 100}},
            "metrics_plane": {"counters": {"slo.breach.premium": 1}}}
    worse = {"slo": {"degraded": {"requests_per_s": 150}},
             "metrics_plane": {"counters": {"slo.breach.premium": 2}}}
    fails = bench_gate.compare(base, worse)
    # both drift inside slo paths: strict despite the rate-marker name
    assert len(fails) == 2
    # outside an slo path the same key is still rate-skipped
    assert bench_gate.compare({"x": {"requests_per_s": 1}},
                              {"x": {"requests_per_s": 9}}) == []


def test_bench_history_collect_and_idempotent_append(tmp_path):
    hist = _load_module(ROOT / "tools" / "bench_history.py",
                        "bench_history_mp")
    art = {"meta": {"run": {"git_sha": "abc1234", "smoke": True,
                            "timestamp": "2026-08-07T00:00:00Z"}},
           "headline": {"p99_ms": 1.5},
           "slo": {"healthy_breaches": {},
                   "degraded": {"detection_delay_s": 0.12,
                                "breaches": {"slo.breach.premium": 1}}}}
    (tmp_path / "BENCH_serve.json").write_text(json.dumps(art))
    row = hist.collect(str(tmp_path))
    assert row["run"]["git_sha"] == "abc1234"
    assert row["benches"]["serve"]["headline"] == {"p99_ms": 1.5}
    assert row["benches"]["serve"]["slo"]["detection_delay_s"] == 0.12
    out = tmp_path / "traj.jsonl"
    assert hist.append(row, str(out)) is True
    assert hist.append(row, str(out)) is False          # same run: skipped
    assert hist.append(row, str(out), force=True) is True
    lines = [json.loads(x) for x in out.read_text().splitlines() if x]
    assert len(lines) == 2 and lines[0] == lines[1]


def test_obs_report_renders_sparklines_and_slo_table(tmp_path):
    rep = _load_module(ROOT / "tools" / "obs_report.py", "obs_report_mp")
    assert rep.sparkline([0.0, 0.5, 1.0], lo=0.0, hi=1.0) == "▁▄█"
    assert rep.sparkline([2.0, 2.0]) == "▁▁"
    assert len(rep.sparkline(list(range(1000)), width=48)) == 48
    art = {
        "meta": {"run": {"git_sha": "abc", "smoke": True,
                         "timestamp": "t"}},
        "metrics_plane": {
            "series": {"tier.nvme.utilization":
                       {"t": [0.1, 0.2], "v": [0.1, 1.0], "n_samples": 2}},
            "latency": {"latency.p": {"count": 3, "p50": 0.01, "p99": 0.02,
                                      "max": 0.03}},
            "counters": {"slo.breach.p": 1},
        },
        "slo": {"degraded": {"t_degradation_s": 0.3,
                             "detection_delay_s": 0.1,
                             "table": [{"tenant": "p", "objective_ms": 50.0,
                                        "target": 0.99, "requests": 10,
                                        "bad": 2, "bad_fraction": 0.2,
                                        "breaches": 1,
                                        "first_alert_t": 0.4}]}},
    }
    text = rep.render(art)
    assert "tier.nvme.utilization" in text and "█" in text
    assert "latency.p" in text
    assert "slo.breach.p=1" in text
    assert "20.0%" in text and "0.400" in text   # SLO table row rendered
    # empty artifact degrades gracefully
    assert "no metrics_plane" in rep.render({})
    p = tmp_path / "BENCH_serve.json"
    p.write_text(json.dumps(art))
    out = tmp_path / "report.txt"
    assert rep.main([str(p), "--out", str(out)]) == 0
    assert out.read_text() == text
