"""Property-based IVF search tests (optional: require ``hypothesis``).

The search path's contracts, stated as properties over random datasets,
partition counts and query batches:

* **exhaustive probing is exact** — with ``nprobe == n_partitions`` every
  candidate is eligible, so recall@k against brute force is 1.0 for any
  data, any seed, any k;
* **index maintenance is invisible** — ``compact()``-ing the index
  fragments, and time-travelling across index manifest versions, never
  changes a search result (ids and distances bit-identical);
* **decode routes are accounting-identical** — the ``decode="numpy"`` and
  ``decode="pallas"`` search paths issue bit-identical logical IO traces
  (same ops, same IOPS, same bytes): the kernel route is a compute detail,
  never an IO detail.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import arrays as A  # noqa: E402
from repro.core.file import WriteOptions  # noqa: E402
from repro.dataset import DatasetWriter, IvfIndex, write_fragments  # noqa: E402
from repro.serve.engine import Retriever  # noqa: E402


def _build(n_rows, dim, n_fragments, n_partitions, seed, decode=None):
    rng = np.random.default_rng(seed)
    vecs = rng.standard_normal((n_rows, dim)).astype(np.float32)
    files = write_fragments({"embedding": A.FixedSizeListArray.build(vecs)},
                            n_fragments, WriteOptions("lance"))
    w = DatasetWriter(files=files, store="tiered", decode=decode)
    ivf = IvfIndex.build(w, "embedding", n_partitions=n_partitions,
                         n_fragments=2, seed=seed)
    r = Retriever(w.reader(), "embedding", index=ivf, decode=decode)
    return w, ivf, r, vecs


def _brute_topk(vecs, queries, k):
    """Exact float64 ground truth (expanded form, stable order)."""
    d = ((vecs[None].astype(np.float64)
          - queries[:, None].astype(np.float64)) ** 2).sum(-1)
    top = np.argsort(d, axis=1, kind="stable")[:, :k]
    return d, top


@settings(max_examples=12, deadline=None)
@given(
    n_rows=st.integers(40, 150),
    dim=st.integers(4, 24),
    n_partitions=st.integers(2, 6),
    k=st.integers(1, 8),
    nq=st.integers(1, 5),
    seed=st.integers(0, 2 ** 16),
)
def test_recall_is_exact_when_probing_every_partition(
        n_rows, dim, n_partitions, k, nq, seed):
    _, _, r, vecs = _build(n_rows, dim, 3, n_partitions, seed)
    rng = np.random.default_rng(seed + 1)
    q = vecs[rng.integers(0, n_rows, nq)] \
        + 0.05 * rng.standard_normal((nq, dim)).astype(np.float32)
    res = r.search(q, k=k, nprobe=n_partitions)
    d64, top = _brute_topk(vecs, q, k)
    hits = 0
    for i in range(nq):
        kth = d64[i, top[i, -1]]
        for rid in res.ids[i]:
            # a retrieved id counts if it is in the exact top-k, or tied
            # with the k-th distance within f32-arithmetic noise
            hits += rid in top[i] or d64[i, rid] <= kth * (1 + 1e-5) + 1e-7
    assert hits == nq * k


@settings(max_examples=10, deadline=None)
@given(
    n_rows=st.integers(50, 140),
    n_partitions=st.integers(3, 7),
    nprobe=st.integers(1, 3),
    seed=st.integers(0, 2 ** 16),
)
def test_search_invariant_under_index_compact_and_versions(
        n_rows, n_partitions, nprobe, seed):
    _, ivf, r, vecs = _build(n_rows, 12, 3, n_partitions, seed)
    rng = np.random.default_rng(seed + 2)
    q = vecs[rng.integers(0, n_rows, 3)]
    before = r.search(q, k=5, nprobe=nprobe)
    v1 = ivf.writer.version
    ivf.compact()  # merges the index fragments -> new index manifest
    assert ivf.writer.version > v1
    after = r.search(q, k=5, nprobe=nprobe)
    np.testing.assert_array_equal(before.ids, after.ids)
    np.testing.assert_array_equal(before.distances, after.distances)
    np.testing.assert_array_equal(before.probes, after.probes)
    # time travel: the pre-compaction index version answers identically
    old = r.search(q, k=5, nprobe=nprobe, index_version=v1)
    np.testing.assert_array_equal(before.ids, old.ids)
    np.testing.assert_array_equal(before.distances, old.distances)


@settings(max_examples=8, deadline=None)
@given(
    n_rows=st.integers(40, 120),
    n_partitions=st.integers(2, 6),
    nprobe=st.integers(1, 4),
    k=st.integers(1, 6),
    seed=st.integers(0, 2 ** 16),
)
def test_decode_routes_issue_identical_logical_io(
        n_rows, n_partitions, nprobe, k, seed):
    w_np, _, r_np, vecs = _build(n_rows, 10, 3, n_partitions, seed,
                                 decode="numpy")
    w_pl, _, r_pl, _ = _build(n_rows, 10, 3, n_partitions, seed,
                              decode="pallas")
    rng = np.random.default_rng(seed + 3)
    q = vecs[rng.integers(0, n_rows, 2)]
    w_np.reset_io()
    w_pl.reset_io()
    res_np = r_np.search(q, k=k, nprobe=nprobe)
    res_pl = r_pl.search(q, k=k, nprobe=nprobe)
    np.testing.assert_array_equal(res_np.ids, res_pl.ids)
    np.testing.assert_array_equal(res_np.distances, res_pl.distances)
    # logical IO trace bit-identical: same (offset, size, phase) ops
    assert w_np.scheduler.ops == w_pl.scheduler.ops
    s_np, s_pl = w_np.io_stats(), w_pl.io_stats()
    assert s_np.n_iops == s_pl.n_iops
    assert s_np.bytes_read == s_pl.bytes_read
