"""Property-based decode-parity tests (optional: require ``hypothesis``).

The row-parallel full-zip decode (frontier walk over row spans, pointer-
doubling entry discovery for scans) must be bit-identical to the retained
sequential per-value walk (``FullZipReader._decode_entries_walk``) over
arbitrary rep/def/null/length shapes.  The whole module is skipped on a bare
interpreter; example-based equivalents live in ``test_take_pipeline.py``.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import arrays as A, types as T  # noqa: E402
from repro.core.file import FileReader, WriteOptions, write_table  # noqa: E402


def _leaf_reader(arr: A.Array, bytes_codec=None):
    opts = WriteOptions("lance-fullzip", bytes_codec=bytes_codec)
    fr = FileReader(write_table({"c": arr}, opts))
    readers = fr._leaf_readers("c")
    return fr, readers


def _walk_eq_rowparallel(fr, reader, n_rows):
    """The oracle check: whole-payload decode, walk vs chain discovery."""
    m = reader.meta
    raw = fr.disk.read(reader.base + m["zip_base"], m["zip_bytes"])
    rw, dw, vw = reader._decode_entries_walk(raw, n_hint=m["n_entries"])
    rp, dp, vp = reader._decode_entries(raw, n_hint=m["n_entries"])
    assert (rw is None) == (rp is None) and (dw is None) == (dp is None)
    if rw is not None:
        np.testing.assert_array_equal(rw, rp)
    if dw is not None:
        np.testing.assert_array_equal(dw, dp)
    if isinstance(vw, A.VarBinaryArray):
        np.testing.assert_array_equal(vw.offsets, vp.offsets)
        np.testing.assert_array_equal(vw.data, vp.data)
    else:
        np.testing.assert_array_equal(vw.values, vp.values)


# -- strategies -------------------------------------------------------------

utf8_rows = st.lists(
    st.one_of(st.none(), st.binary(max_size=40)), min_size=1, max_size=120)

nested_rows = st.lists(
    st.one_of(
        st.none(),
        st.lists(st.one_of(st.none(), st.binary(max_size=24)), max_size=6),
    ),
    min_size=1, max_size=80)


@settings(max_examples=40, deadline=None)
@given(utf8_rows)
def test_flat_var_width_walk_parity(rows):
    arr = A.from_pylist(rows, T.Binary(True))
    fr, readers = _leaf_reader(arr)
    _walk_eq_rowparallel(fr, readers[0], len(rows))


@settings(max_examples=40, deadline=None)
@given(nested_rows, st.randoms(use_true_random=False))
def test_nested_var_width_walk_parity(rows, rnd):
    """Random rep/def/null/length shapes: list<binary> rows (null lists,
    empty lists, null items, empty values) through take and scan must match
    the walk and the pylist oracle."""
    arr = A.from_pylist(rows, T.List(T.Binary(True)))
    fr, readers = _leaf_reader(arr)
    for r in readers:
        _walk_eq_rowparallel(fr, r, len(rows))
    want = A.to_pylist(arr)
    assert A.to_pylist(fr.scan("c")) == want
    # windowed scan with a tail-carrying chunk size
    assert A.to_pylist(fr.scan("c", io_chunk=rnd.randrange(8, 128))) == want
    take = [rnd.randrange(len(rows)) for _ in range(min(16, 2 * len(rows)))]
    got = A.to_pylist(fr.take("c", np.array(take, dtype=np.int64)))
    assert got == [want[i] for i in take]


@settings(max_examples=20, deadline=None)
@given(utf8_rows)
def test_var_width_fsst_walk_parity(rows):
    """Transparent per-value compression (fsst) under the row-parallel
    decode: stored lengths differ from logical lengths, so this exercises
    the length-prefix path with a real codec in the loop."""
    arr = A.from_pylist(rows, T.Utf8(True))
    fr, readers = _leaf_reader(arr, bytes_codec="fsst_lite")
    _walk_eq_rowparallel(fr, readers[0], len(rows))
    assert A.to_pylist(fr.scan("c")) == A.to_pylist(arr)
