"""End-to-end behaviour of the paper's system: every structural encoding
roundtrips every data type, and the IOPS / read-amplification / search-cache
claims from the paper hold exactly."""

import numpy as np
import pytest

from repro.core import arrays as A, types as T
from repro.core.adaptive import FULLZIP_THRESHOLD_BYTES, choose_encoding
from repro.core.file import FileReader, WriteOptions, write_table
from repro.core.shred import shred
from repro.data import synth

rng = np.random.default_rng(42)
N = 600
TAKE = rng.choice(N, 31, replace=False)

ENCODINGS = [
    ("lance", WriteOptions("lance")),
    ("lance-miniblock", WriteOptions("lance-miniblock")),
    ("lance-fullzip", WriteOptions("lance-fullzip")),
    ("lance-fullzip-fsst", WriteOptions("lance-fullzip", bytes_codec="fsst_lite")),
    ("parquet", WriteOptions("parquet")),
    ("parquet-dict", WriteOptions("parquet", dict_encode=True)),
    ("arrow", WriteOptions("arrow")),
    ("arrow-zstd", WriteOptions("arrow", arrow_compress=True)),
]

TYPES = ["scalar", "string", "scalar-list", "string-list", "vector"]


@pytest.fixture(scope="module")
def datasets():
    return {t: synth.paper_type(t, N, seed=7) for t in TYPES}


@pytest.mark.parametrize("encname,opts", ENCODINGS, ids=[e[0] for e in ENCODINGS])
@pytest.mark.parametrize("tname", TYPES)
def test_roundtrip(encname, opts, tname, datasets):
    arr = datasets[tname]
    fr = FileReader(write_table({"c": arr}, opts))
    want = A.to_pylist(arr)
    assert A.to_pylist(fr.scan("c")) == want
    got = A.to_pylist(fr.take("c", TAKE))
    assert got == [want[i] for i in TAKE]


# ---------------------------------------------------------------------------
# the paper's quantitative claims
# ---------------------------------------------------------------------------


def _take_stats(arr, opts, rows=TAKE):
    fr = FileReader(write_table({"c": arr}, opts))
    fr.reset_io()
    fr.take("c", rows)
    return fr, fr.io_stats()


def test_fullzip_fixed_width_is_1_iop(datasets):
    """'At most 1 IOP for random access to a fixed-width column' (§4)."""
    for t in ["scalar", "vector"]:
        fr, st = _take_stats(datasets[t], WriteOptions("lance-fullzip"))
        assert st.n_iops == len(TAKE)
        assert st.max_phase == 1
        assert fr.search_cache_bytes() == 0  # §4.2.4: no search cache


def test_fullzip_variable_width_is_2_iops(datasets):
    """'At most 2 IOPS for random access to a variable-width column' —
    regardless of nesting (§4)."""
    for t in ["string", "scalar-list", "string-list"]:
        fr, st = _take_stats(datasets[t], WriteOptions("lance-fullzip"))
        assert st.n_iops == 2 * len(TAKE), t
        assert st.max_phase == 2
        assert fr.search_cache_bytes() == 0


def test_fullzip_nesting_invariance():
    """Performance is 'consistent regardless of how many levels of nesting'."""
    vals = [[{"s": ["ab", "cd"]}], None, [{"s": []}]] * 50
    typ = T.List(T.Struct((("s", T.List(T.utf8())),)))
    arr = A.from_pylist(vals, typ)
    rows = np.arange(0, 150, 7)
    fr, st = _take_stats(arr, WriteOptions("lance-fullzip"), rows=rows)
    assert st.n_iops == 2 * len(rows)
    assert st.max_phase == 2


def test_arrow_list_string_is_5_iops_3_phases():
    """Fig 4: a List<String> 'which contains nulls in each layer' needs 5
    IOPS issued in 3 dependent phases."""
    vals = [["ab", None, "cd"], None, ["xyz"], []] * 50
    arr = A.from_pylist(vals, T.List(T.utf8()))
    fr, st = _take_stats(arr, WriteOptions("arrow"), rows=np.array([5]))
    assert st.n_iops == 5  # list validity, list offsets, str validity,
    #                        str offsets, str data
    assert st.max_phase == 3
    # the same nulls-in-each-layer column in Lance full-zip: 2 IOPS, 2 phases
    fr2, st2 = _take_stats(arr, WriteOptions("lance-fullzip"), rows=np.array([5]))
    assert st2.n_iops == 2 and st2.max_phase == 2


def test_parquet_one_page_per_row():
    """§3.1: page index maps a row to exactly one page -> 1 IOP per row (for
    rows in distinct pages)."""
    arr = synth.paper_type("vector", N, seed=9)  # 3 KiB values: 1-2 rows/page
    fr, st = _take_stats(arr, WriteOptions("parquet", page_bytes=8192),
                         rows=np.array([1, 100, 200, 300, 400]))
    assert st.n_iops == 5
    assert st.max_phase == 1


def test_parquet_dict_needs_extra_fetch(datasets):
    """§6.1.1: cold dictionary page must be fetched per take."""
    arr = datasets["string"]
    fr, st = _take_stats(arr, WriteOptions("parquet", dict_encode=True),
                         rows=np.array([3]))
    assert st.n_iops == 2  # dict page + data page
    fr2 = FileReader(write_table({"c": arr}, WriteOptions("parquet", dict_encode=True)),
                     dict_cached=True)
    fr2.take("c", np.array([3]))  # warm the cache
    fr2.reset_io()
    fr2.take("c", np.array([4]))
    assert fr2.io_stats().n_iops == 1  # Lance-style: dict in search cache


def test_adaptive_threshold(datasets):
    """§4: >=128 B/value -> full-zip, below -> mini-block."""
    small = shred(datasets["scalar"])[0]
    big = shred(datasets["vector"])[0]
    assert choose_encoding(small) == "miniblock"
    assert choose_encoding(big) == "fullzip"
    # the file writer applies it
    fr = FileReader(write_table({"c": datasets["vector"]}, WriteOptions("lance")))
    assert fr.columns["c"]["leaves"][0]["meta"]["encoding"] == "fullzip"
    fr = FileReader(write_table({"c": datasets["scalar"]}, WriteOptions("lance")))
    assert fr.columns["c"]["leaves"][0]["meta"]["encoding"] == "miniblock"


def test_search_cache_budget():
    """§2.3: search cache stays well under 1% of data for scalar mini-blocks."""
    arr = synth.paper_type("scalar", 50_000, seed=11)
    fr = FileReader(write_table({"c": arr}, WriteOptions("lance")))
    assert fr.search_cache_bytes() / fr.data_bytes() < 0.01


def test_miniblock_chunks_within_limits():
    """§4.2.1: chunks are <=4096 values, 8-byte aligned words, <=32 KiB."""
    arr = synth.paper_type("string", 20_000, seed=13)
    fr = FileReader(write_table({"c": arr}, WriteOptions("lance-miniblock")))
    meta = fr.columns["c"]["leaves"][0]["meta"]
    for cm in meta["chunks"]:
        assert cm["n_entries"] <= 4096
        assert cm["words"] * 8 <= 32 * 1024


def test_struct_packing_tradeoff():
    """§4.3/Fig 18: packed struct fetches all fields in 1 IOP; single-field
    scan reads the whole stride."""
    n = 400
    children = [(f"f{i}", A.PrimitiveArray.build(
        rng.integers(0, 1 << 30, n).astype(np.int64), nullable=False))
        for i in range(4)]
    arr = A.StructArray.build(children, nullable=False)
    fb = write_table({"s": arr}, WriteOptions("lance", packed_columns=("s",)))
    fr = FileReader(fb)
    fr.reset_io()
    rows = np.arange(0, n, 37)
    got = fr.take("s", rows)
    st = fr.io_stats()
    assert st.n_iops == len(rows)  # 1 IOP for ALL fields
    assert A.to_pylist(got) == [A.to_pylist(arr)[i] for i in rows]
    fr.reset_io()
    fr.scan_packed_field("s", ["f0"])
    assert fr.io_stats().bytes_read == fr.data_bytes()  # reads everything


def test_multi_column_table():
    table = {
        "id": synth.paper_type("scalar", N, seed=1),
        "text": synth.paper_type("string", N, seed=2),
        "emb": synth.paper_type("vector", N, seed=3),
    }
    fr = FileReader(write_table(table, WriteOptions("lance")))
    for name, arr in table.items():
        assert A.to_pylist(fr.take(name, TAKE)) == [A.to_pylist(arr)[i] for i in TAKE]
