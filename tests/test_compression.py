"""Codec roundtrips + the transparency property (a single value can be
sliced out of a transparent stream — paper §2.2).

Property-based (hypothesis) variants live in
``test_compression_properties.py`` so this module runs on a bare
interpreter."""

import numpy as np
import pytest

from repro.core.compression import (
    BYTES_CODECS,
    FIXED_CODECS,
    Encoded,
    bitpack,
    bitunpack,
    get_bytes_codec,
    get_fixed_codec,
)

rng = np.random.default_rng(0)


@pytest.mark.parametrize("bits", [1, 2, 3, 7, 8, 13, 17, 32, 48, 63])
def test_bitpack_roundtrip(bits):
    v = rng.integers(0, 2 ** min(bits, 62), 777, dtype=np.uint64)
    assert (bitunpack(bitpack(v, bits), len(v), bits) == v).all()


FIXED_GEN = {
    "plain": lambda n: rng.standard_normal(n).astype(np.float32),
    "bitpack": lambda n: rng.integers(0, 5000, n).astype(np.uint32),
    "bytepack": lambda n: rng.integers(-5000, 5000, n).astype(np.int64),
    "delta_bitpack": lambda n: np.cumsum(rng.integers(0, 9, n)).astype(np.int64),
    "rle": lambda n: np.repeat(rng.integers(0, 5, max(1, n // 7)),
                               rng.integers(1, 15, max(1, n // 7)))[:n].astype(np.int32),
    "dict": lambda n: rng.choice([3, 14, 15, 92, 65], n).astype(np.int64),
}


@pytest.mark.parametrize("name", list(FIXED_GEN))
@pytest.mark.parametrize("n", [0, 1, 17, 1000])
def test_fixed_codec_roundtrip(name, n):
    c = get_fixed_codec(name)
    v = FIXED_GEN[name](n)
    if name == "rle" and n == 0:
        v = v[:0]
    enc = c.encode(v)
    out = c.decode(enc, len(v))
    assert (np.asarray(out) == v).all()


def _values(n):
    vals = []
    for i in range(n):
        k = int(rng.integers(0, 60))
        vals.append(bytes(rng.integers(97, 110, k, dtype=np.uint8)) * int(rng.integers(1, 3)))
    return vals


@pytest.mark.parametrize("name", list(BYTES_CODECS))
@pytest.mark.parametrize("n", [0, 1, 50])
def test_bytes_codec_roundtrip(name, n):
    c = get_bytes_codec(name)
    vals = _values(n)
    lengths = np.array([len(v) for v in vals], dtype=np.int64)
    data = np.frombuffer(b"".join(vals), np.uint8) if vals else np.zeros(0, np.uint8)
    enc = c.encode(lengths, data)
    stored = enc.out_lengths if enc.out_lengths is not None else lengths
    out_lens, out_data = c.decode(enc, stored)
    assert (out_lens == lengths).all()
    assert out_data.tobytes() == data.tobytes()


@pytest.mark.parametrize("name", [n for n, c in BYTES_CODECS.items() if c.transparent])
def test_transparency_single_value_slice(name):
    """Transparent codecs must decode value i from its slice alone (this is
    what full-zip relies on, paper 4.1.3)."""
    c = get_bytes_codec(name)
    vals = _values(40)
    lengths = np.array([len(v) for v in vals], dtype=np.int64)
    data = np.frombuffer(b"".join(vals), np.uint8) if vals else np.zeros(0, np.uint8)
    enc = c.encode(lengths, data)
    offs = np.zeros(len(vals) + 1, np.int64)
    np.cumsum(enc.out_lengths, out=offs[1:])
    for i in [0, 7, 39]:
        piece = enc.data[offs[i]: offs[i + 1]]
        _, od = c.decode(Encoded(piece, enc.meta), enc.out_lengths[i: i + 1])
        assert od.tobytes() == vals[i]


def test_fsst_escape_roundtrip():
    """FSST-lite must roundtrip arbitrary binary (escape path) — example
    cases; the hypothesis sweep is in test_compression_properties.py."""
    c = get_bytes_codec("fsst_lite")
    blobs = [b"", b"\xff" * 32, bytes(range(256)) * 3, b"ababab" * 50]
    for blob in blobs:
        vals = [blob[: len(blob) // 2], blob[len(blob) // 2 :]]
        lengths = np.array([len(v) for v in vals], dtype=np.int64)
        data = np.frombuffer(blob, np.uint8) if blob else np.zeros(0, np.uint8)
        enc = c.encode(lengths, data)
        out_lens, out_data = c.decode(enc, enc.out_lengths)
        assert out_data.tobytes() == blob
        assert (out_lens == lengths).all()
