"""Property-based codec tests (optional: require ``hypothesis``).

The whole module is skipped on a bare interpreter; the example-based
equivalents stay in ``test_compression.py``."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.compression import get_bytes_codec, get_fixed_codec  # noqa: E402

rng = np.random.default_rng(0)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(0, 2**40), max_size=200))
def test_bytepack_property(xs):
    v = np.array(xs, dtype=np.int64)
    c = get_fixed_codec("bytepack")
    enc = c.encode(v)
    assert (np.asarray(c.decode(enc, len(v))) == v).all()
    # byte-aligned: encoded width is an integer number of bytes
    if len(v):
        assert enc.data.nbytes == c.encoded_width(enc) * len(v)


@settings(max_examples=30, deadline=None)
@given(st.binary(max_size=400), st.integers(1, 7))
def test_fsst_arbitrary_bytes(blob, nvals):
    """FSST-lite must roundtrip arbitrary binary (escape path)."""
    c = get_bytes_codec("fsst_lite")
    cuts = sorted(rng.integers(0, len(blob) + 1, nvals - 1).tolist()) if nvals > 1 else []
    bounds = [0] + cuts + [len(blob)]
    vals = [blob[bounds[i]: bounds[i + 1]] for i in range(len(bounds) - 1)]
    lengths = np.array([len(v) for v in vals], dtype=np.int64)
    data = np.frombuffer(blob, np.uint8) if blob else np.zeros(0, np.uint8)
    enc = c.encode(lengths, data)
    out_lens, out_data = c.decode(enc, enc.out_lengths)
    assert out_data.tobytes() == blob
    assert (out_lens == lengths).all()
